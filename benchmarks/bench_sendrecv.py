"""Paper Fig. 15 (small-message latency) & Fig. 16 (per-byte cost vs
message size, zero-copy thresholds), on a real ring + SimSocket pair."""

from benchmarks.common import emit, emit_attribution, section
from repro.core import IoUring, SetupFlags, Timeline
from repro.core.backends import NICSpec, SimNetwork, SimSocket
from repro.core import ring as R
from repro.core.sqe import SqeFlags


def make_pair(setup):
    tl = Timeline()
    net = SimNetwork(tl, 2, NICSpec())
    sa, sb = SimSocket.pair(net, 0, 1)
    ra = IoUring(tl, setup=setup)
    rb = IoUring(tl, setup=setup)
    ra.register_device(4, sa)
    rb.register_device(4, sb)
    return tl, ra, rb


def pingpong(setup, *, n=64, size=8, poll_first=False):
    tl, ra, rb = make_pair(setup)
    t0 = tl.now
    for _ in range(n):
        sqe = ra.get_sqe()
        R.prep_send(sqe, 4, size, user_data=1)
        ra.submit()
        # peer receives then replies
        sqe = rb.get_sqe()
        R.prep_recv(sqe, 4, size, user_data=2,
                    flags=SqeFlags.POLL_FIRST if poll_first
                    else SqeFlags.NONE)
        rb.submit()
        rb.wait_cqe()
        sqe = rb.get_sqe()
        R.prep_send(sqe, 4, size, user_data=3)
        rb.submit()
        sqe = ra.get_sqe()
        R.prep_recv(sqe, 4, size, user_data=4)
        ra.submit()
        ra.wait_cqe()
    rtt = (tl.now - t0) / n
    return rtt * 1e6, ra


def run():
    section("TCP-like ping-pong latency, 8 B (paper Fig. 15)")
    for name, setup in [("DeferTR", SetupFlags.DEFER_TASKRUN),
                        ("CoopTR", SetupFlags.COOP_TASKRUN),
                        ("default", SetupFlags.NONE)]:
        rtt, _ = pingpong(setup)
        emit(f"fig15/{name}/rtt_us", round(rtt, 2), "")
    rtt, ring = pingpong(SetupFlags.DEFER_TASKRUN, poll_first=True)
    emit("fig15/DeferTR+PollFirst/rtt_us", round(rtt, 2),
         "skips speculative inline attempt")
    # paper §4.6: PollFirst cuts CPU cycles when the data is KNOWN not to
    # be ready yet (RPC pattern: recv posted before the response exists)
    cyc = {}
    for pf in (False, True):
        tl, ra, rb = make_pair(SetupFlags.DEFER_TASKRUN)
        n = 64
        for _ in range(n):
            sqe = ra.get_sqe()
            R.prep_recv(sqe, 4, 8, user_data=1,
                        flags=SqeFlags.POLL_FIRST if pf
                        else SqeFlags.NONE)
            ra.submit()                    # speculative attempt wasted here
            sqe = rb.get_sqe()
            R.prep_send(sqe, 4, 8, user_data=2)
            rb.submit()
            ra.wait_cqe()
        cyc[pf] = ra.stats.cpu_seconds_app
    emit("fig15/PollFirst/recv_cpu_saving",
         round(cyc[False] / max(cyc[True], 1e-12), 2),
         "paper: up to 1.5x fewer kernel recv-path cycles")

    section("per-byte recv cost vs message size (paper Fig. 16 right)")
    for size in (64, 256, 1024, 4096, 16_384, 65_536):
        rows = {}
        for mode in ("single", "multishot", "zc"):
            tl, ra, rb = make_pair(SetupFlags.DEFER_TASKRUN)
            n = 32
            # pre-send n messages from the peer
            for _ in range(n):
                sqe = rb.get_sqe()
                R.prep_send(sqe, 4, size, user_data=9)
            rb.submit()
            if mode == "multishot":
                sqe = ra.get_sqe()
                R.prep_recv(sqe, 4, size, user_data=1,
                            flags=SqeFlags.MULTISHOT)
                ra.submit()
                ra.wait_cqes(n)
            else:
                for _ in range(n):
                    sqe = ra.get_sqe()
                    R.prep_recv(sqe, 4, size, user_data=1,
                                zero_copy=(mode == "zc"))
                    ra.submit()
                    ra.wait_cqe()
            rows[mode] = ra.stats.cpu_seconds_app * 3.7e9 / (n * size)
        best = min(rows, key=rows.get)
        for mode, cpb in rows.items():
            emit(f"fig16/recv/{mode}/size={size}/cycles_per_byte",
                 round(cpb, 4), "best" if mode == best else "")

    section("per-byte send cost vs message size (paper Fig. 16)")
    for size in (64, 256, 1024, 4096, 16_384, 262_144, 1 << 20):
        for zc in (False, True):
            tl, ra, rb = make_pair(SetupFlags.DEFER_TASKRUN)
            n = 32
            for _ in range(n):
                sqe = ra.get_sqe()
                R.prep_send(sqe, 4, size, user_data=1, zero_copy=zc)
                ra.submit()
                # SEND_ZC posts two CQEs: completion (MORE) + the
                # deferred buffer-release ZC_NOTIF
                ra.wait_cqes(2 if zc else 1)
            cpb = ra.stats.cpu_seconds_app * 3.7e9 / (n * size)
            label = "zc" if zc else "copy"
            emit(f"fig16/send/{label}/size={size}/cycles_per_byte",
                 round(cpb, 4),
                 "zc wins" if zc and size > 1024 else "")
            if size == 262_144:
                # one representative point: copy mode is all
                # bounce_copy, zc mode trades it for zc_setup
                emit_attribution(f"fig16/send/{label}/size={size}",
                                 ra.stats.attribution,
                                 ra.stats.cpu_seconds_app +
                                 ra.stats.cpu_seconds_sqpoll)
