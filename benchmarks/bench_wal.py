"""Paper Fig. 9, end-to-end: durable transactions through the WAL
subsystem (repro.wal) instead of the isolated micro-benchmark in
bench_durable.py.

Three sweeps:

  fig9wal/paths   per-commit latency of the three durable-write paths
                  on the same workload — write+fsync (+WAL, io_worker
                  fallback), linked write→fsync (+GroupCommit), and
                  passthrough write + NVMe flush (+PassthruFlush) — on
                  consumer vs enterprise (PLP) SSDs.  Expected ordering
                  on PLP hardware: passthru < linked < write+fsync.

  fig9wal/group   fsync amortization vs fiber count: group commit's
                  achieved group size and fsyncs/txn as concurrency
                  grows (1 → 128 fibers).

  fig9wal/tpcc    durable TPC-C: throughput of the non-durable engine
                  vs the three durability rungs, plus WAL volume and
                  the WAL-induced eviction waits.

  fig9wal/adaptive  group size vs commit latency under the adaptive
                  flush policy (ROADMAP satellite): the leader defers
                  the flush on the inflight-vs-queued signal
                  (core.adaptive.AdaptiveFlush) instead of flushing
                  everything appended, trading commit latency for
                  fsync amortization.

  fig9wal/mc      multi-core durability: cross-core commit queues into
                  ONE leader fiber — fsyncs/txn stays amortized while
                  tps scales with the cores.
"""

from dataclasses import replace

from benchmarks.common import emit, emit_attribution, section
from repro.core import NVMeSpec
from repro.storage.engine import EngineConfig, StorageEngine
from repro.storage.workloads import TPCCLite, ycsb_update_txn

SSDS = {
    "consumer": dict(plp=False, fsync_lat=1.2e-3),
    "enterprise": dict(plp=True, fsync_lat=30e-6),
}

RUNGS = [("+WAL", "wal"), ("+GroupCommit", "group"),
         ("+PassthruFlush", "passthru-flush")]


def _engine(name, durability, *, n_fibers=128, n_tuples=50_000,
            frames=2048, spec=None, adaptive_commit=False):
    cfg = EngineConfig(
        name, n_fibers=n_fibers, pool_frames=frames,
        durability=durability,
        fixed_bufs=durability in ("group", "passthru-flush"),
        passthrough=durability == "passthru-flush",
        adaptive_commit=adaptive_commit)
    return StorageEngine(cfg, n_tuples=n_tuples, spec=spec)


def run(n_txns: int = 768):
    section("WAL durable writes, end-to-end (paper Fig. 9)")
    # -- per-commit latency of the three paths, per SSD class
    for ssd, kw in SSDS.items():
        for name, dur in RUNGS:
            eng = _engine(name, dur, spec=NVMeSpec(**kw))
            res = eng.run_fibers(lambda rng: ycsb_update_txn(eng, rng),
                                 n_txns)
            emit(f"fig9wal/paths/{ssd}/{name}/commit_us",
                 round(res["commit_wait_us"], 1),
                 f"fsyncs={res['fsyncs']} group={res['group_size']:.1f} "
                 f"workers={res['worker_fallbacks']}")

    # -- group-size scaling: fsync amortization vs concurrency
    for n_fibers in (1, 8, 32, 128):
        eng = _engine("+GroupCommit", "group", n_fibers=n_fibers,
                      spec=NVMeSpec(**SSDS["enterprise"]))
        res = eng.run_fibers(lambda rng: ycsb_update_txn(eng, rng),
                             n_txns)
        emit(f"fig9wal/group/fibers={n_fibers}/fsyncs_per_txn",
             round(res["fsyncs_per_txn"], 3),
             f"group={res['group_size']:.1f} tps={res['tps']:.0f} "
             f"commit_us={res['commit_wait_us']:.0f}")

    # -- adaptive flush: group size vs commit latency, eager vs adaptive
    for ssd in ("enterprise", "consumer"):
        for n_fibers in (8, 32, 128):
            row = {}
            for label, adaptive in (("eager", False), ("adaptive", True)):
                eng = _engine("+GroupCommit", "group", n_fibers=n_fibers,
                              spec=NVMeSpec(**SSDS[ssd]),
                              adaptive_commit=adaptive)
                res = eng.run_fibers(
                    lambda rng, e=eng: ycsb_update_txn(e, rng), n_txns)
                row[label] = res
                emit(f"fig9wal/adaptive/{ssd}/fibers={n_fibers}/"
                     f"{label}/group", round(res["group_size"], 1),
                     f"commit_us={res['commit_wait_us']:.0f} "
                     f"fsyncs_per_txn={res['fsyncs_per_txn']:.3f} "
                     f"tps={res['tps']:.0f}")

    # -- multi-core group commit: one leader fiber, cross-core queues
    for n in (1, 4):
        cfg = replace(EngineConfig.multicore(n, durability="group",
                                             fixed_bufs=True),
                      pool_frames=2048)
        eng = StorageEngine(cfg, n_tuples=50_000,
                            spec=NVMeSpec(**SSDS["enterprise"]))
        res = eng.run_fibers(lambda rng, e=eng: ycsb_update_txn(e, rng),
                             n_txns)
        emit(f"fig9wal/mc/cores={n}/tps", round(res["tps"]),
             f"fsyncs_per_txn={res['fsyncs_per_txn']:.3f} "
             f"group={res['group_size']:.1f} "
             f"commit_us={res['commit_wait_us']:.0f}")

    # -- durable TPC-C (the PostgreSQL-case-study shape: WAL dominates)
    W = 4
    n_rows = W * (TPCCLite.ITEMS_PER_WH + TPCCLite.CUST_PER_WH)
    for name, dur in [("+BatchSubmit", "none")] + RUNGS:
        eng = _engine(name, dur, n_tuples=n_rows + 100, frames=4096,
                      spec=NVMeSpec(**SSDS["enterprise"]))
        tp = TPCCLite(eng, W)
        res = eng.run_fibers(lambda rng: tp.txn(rng), n_txns)
        extra = ""
        if dur != "none":
            extra = (f"fsyncs={res['fsyncs']} "
                     f"group={res['group_size']:.1f} "
                     f"log_mb={res['log_mb']:.2f} "
                     f"evict_waits={res['wal_evict_waits']}")
        emit(f"fig9wal/tpcc/W={W}/{name}/tps", round(res["tps"]), extra)
        # worker_fallback share separates +WAL (plain fsync -> io-wq)
        # from the linked / passthrough rungs (GL3)
        emit_attribution(f"fig9wal/tpcc/W={W}/{name}", res["attribution"],
                         res["app_cpu_s"] + res["sqpoll_cpu_s"])
