"""Paper §2.1 chart: CPU cycles/op vs submission batch size (~5-6x at 16)."""

from benchmarks.common import emit, section
from repro.core import IoUring, SetupFlags, SimNVMe, Timeline
from repro.core import ring as R


def run():
    section("batching: cycles/op vs batch size (paper §2.1)")
    for op in ("nop", "read"):
        base = None
        for batch in (1, 2, 4, 8, 16, 32, 64):
            tl = Timeline()
            ring = IoUring(tl, setup=SetupFlags.DEFER_TASKRUN)
            ring.register_device(3, SimNVMe(tl))
            n = 256
            for s in range(0, n, batch):
                for i in range(batch):
                    sqe = ring.get_sqe()
                    if op == "nop":
                        R.prep_nop(sqe)
                    else:
                        R.prep_read(sqe, 3, bytearray(4096),
                                    (s + i) * 4096, 4096)
                ring.submit()
                ring.wait_cqes(batch)
            cyc = ring.stats.cpu_seconds_app / n * 3.7e9
            if base is None:
                base = cyc
            emit(f"batching/{op}/cycles_per_op/batch={batch}", round(cyc),
                 f"speedup={base / cyc:.2f}x")
