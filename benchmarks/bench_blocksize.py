"""Paper Fig. 8: single-thread throughput & cycles/byte vs block size,
including the io_worker fallback cliffs (>512 KiB)."""

from benchmarks.common import emit, section
from repro.core import IoUring, SetupFlags, SimNVMe, Timeline
from repro.core import ring as R

KiB = 1024


def run():
    section("block size sweep (paper Fig. 8)")
    for write in (False, True):
        op = "write" if write else "read"
        for bs in (4 * KiB, 16 * KiB, 64 * KiB, 128 * KiB, 256 * KiB,
                   512 * KiB, 1024 * KiB):
            tl = Timeline()
            ring = IoUring(tl, setup=SetupFlags.DEFER_TASKRUN |
                           SetupFlags.IOPOLL)
            ring.register_device(3, SimNVMe(tl))
            n = max(8, (64 << 20) // bs)
            depth = 16
            done = 0
            inflight = 0
            i = 0
            while done < n:
                while inflight < depth and i < n:
                    sqe = ring.get_sqe()
                    if sqe is None:
                        break
                    f = R.prep_write if write else R.prep_read
                    f(sqe, 3, bytearray(bs), i * bs, bs)
                    sqe.cmd = "passthru"
                    i += 1
                    inflight += 1
                ring.submit()
                ring.wait_cqe()
                done += 1
                inflight -= 1
            gib = n * bs / tl.now / 2**30
            cpb = ring.stats.cpu_seconds_app * 3.7e9 / (n * bs)
            emit(f"fig8/{op}/bs={bs//KiB}KiB/gib_s", round(gib, 1),
                 f"cycles_per_byte={cpb:.3f} "
                 f"workers={ring.stats.worker_fallbacks}")
