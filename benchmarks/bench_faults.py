"""Fault-injection sweeps (repro.core.faults): what error recovery
costs, and proof that it never costs durability.

  faults/wal       fault-intensity sweep on +GroupCommit — the same
                   YCSB update workload at per-op fault rates 0, 0.5%
                   and 2% (transient EIO on reads/writes, fsync
                   failures, short reads, latency spikes).  Rows:
                   txn p99/p999, goodput (committed txn/s), retry and
                   injection tallies.  The rate=0 run must be BIT-
                   IDENTICAL to the no-fault-plane baseline (an
                   all-zero spec builds no plane and consumes no RNG)
                   — asserted here, not just banded.

  faults/passthru  +PassthruFlush under NVMe passthrough ENOTSUP /
                   timeout faults: the pool's read path and the WAL's
                   flush path degrade to the regular read / linked
                   write->fsync path, counted as fallbacks (>= 1
                   asserted — the degrade path must actually run).

  faults/semisync  +SemiSync under a scripted link-flap storm with an
                   ack-timeout watchdog: the sender reconnects with
                   backoff and re-ships from the acked horizon, and
                   the cluster degrades to async acking rather than
                   stall commits (degrades >= 1 asserted), then
                   re-promotes once the standby catches up.

  faults/storm     the durability audit: crash the engine MID-STORM
                   (2% write EIO + 1% fsync failures + 1% read EIO),
                   run redo recovery on the frozen images, and count
                   acked txns missing from the winner set.  The
                   acked_lost row must be 0 — scripts/check.sh fails
                   the build otherwise (the fsyncgate property:
                   a commit whose fsync failed is never acked until a
                   fully-successful retry made it durable).

short_write is deliberately 0 on engine sweeps: a torn DATA page
(fresh LSN header, stale tail) defeats LSN-gated redo by design —
see docs/robustness.md.  Short WAL writes are covered by the CRC
framing and exercised in tests/test_faults.py.
"""

import numpy as np

from benchmarks.common import emit, emit_attribution, section
from repro.core import NVMeSpec
from repro.core.faults import FaultSpec
from repro.observe.advisor import diagnose, report_from_result
from repro.storage.engine import EngineConfig, StorageEngine
from repro.storage.workloads import ycsb_update_txn
from repro.wal import recover

ENTERPRISE = dict(plp=True, fsync_lat=30e-6)

#: per-op fault intensity grid for the faults/wal sweep; labels are
#: the row parameter so smoke and full runs line up.  The top rate is
#: high enough that even a 96-txn smoke run injects a storm the
#: advisor must flag.
RATES = [("0", 0.0), ("0.01", 0.01), ("0.05", 0.05)]


def _engine(durability, *, faults=None, passthrough=False, n_fibers=64,
            n_tuples=50_000, frames=1024):
    cfg = EngineConfig(
        "+GroupCommit" if durability == "group" else "+PassthruFlush",
        n_fibers=n_fibers, pool_frames=frames, durability=durability,
        fixed_bufs=True, passthrough=passthrough, faults=faults)
    return StorageEngine(cfg, n_tuples=n_tuples,
                         spec=NVMeSpec(**ENTERPRISE))


def _timed(eng, lat):
    """Wrap the YCSB txn with sim-time stamps so the sweep can report
    whole-txn latency percentiles (commit wait + retry backoff)."""
    def txn(rng):
        t0 = eng.tl.now
        yield from ycsb_update_txn(eng, rng)
        lat.append(eng.tl.now - t0)
    return txn


def _pct(lat, q):
    xs = sorted(lat)
    return xs[min(len(xs) - 1, int(q * len(xs)))] * 1e6


def _retries(res):
    return (res.get("wal_io_retries", 0) +
            res.get("pool_read_retries", 0) +
            res.get("pool_write_retries", 0))


def run(n_txns: int = 512):
    section("fault-intensity sweep, +GroupCommit (faults/wal)")
    baseline = None
    for label, r in RATES:
        spec = FaultSpec(seed=7, read_eio=r, write_eio=r, fsync_fail=r,
                         short_read=r, latency_spike=r)
        lat = []
        eng = _engine("group", faults=spec)
        res = eng.run_fibers(_timed(eng, lat), n_txns)
        if label == "0":
            # an all-zero spec builds NO plane: this run must be
            # bit-identical to one with faults=None, not merely close
            blat = []
            beng = _engine("group")
            baseline = beng.run_fibers(_timed(beng, blat), n_txns)
            assert (res["tps"], lat) == (baseline["tps"], blat), \
                "zero-rate fault spec perturbed the baseline"
            assert eng.faults is None and "faults_injected" not in res
        emit(f"faults/wal/rate={label}/p99_us", round(_pct(lat, 0.99), 1),
             f"p50={_pct(lat, 0.50):.0f}us")
        emit(f"faults/wal/rate={label}/p999_us",
             round(_pct(lat, 0.999), 1))
        emit(f"faults/wal/rate={label}/goodput_tps", round(res["tps"]),
             f"commits={res.get('commits', 0)}")
        emit(f"faults/wal/rate={label}/injected",
             res.get("faults_injected", 0),
             f"error_cqes={res.get('error_cqes', 0)} "
             f"short_cqes={res.get('short_cqes', 0)}")
        emit(f"faults/wal/rate={label}/retries", _retries(res),
             f"wal={res.get('wal_io_retries', 0)} "
             f"pool_r={res.get('pool_read_retries', 0)} "
             f"pool_w={res.get('pool_write_retries', 0)} "
             f"flush_errors={res.get('wal_flush_errors', 0)}")
        if r > 0:
            assert res["faults_injected"] > 0, f"rate {r}: no faults hit"
    # the advisor must call out the storm at the top intensity
    findings = diagnose(report_from_result(res))
    top = findings[0] if findings else None
    emit("faults/wal/rate=0.05/diagnosis", top.rung if top else "ok",
         f"rule={top.rule} severity={top.severity:.3f}"
         if top else "no rule fired")
    assert any(f.rule == "transient-error-storm" for f in findings), \
        "advisor missed the 5% error storm"
    emit_attribution("faults/wal/rate=0.05", res["attribution"],
                     res["app_cpu_s"] + res["sqpoll_cpu_s"])

    section("NVMe passthrough degrade, +PassthruFlush (faults/passthru)")
    spec = FaultSpec(seed=11, passthru_enotsup=0.05,
                     passthru_timeout=0.02)
    lat = []
    eng = _engine("passthru-flush", faults=spec, passthrough=True)
    res = eng.run_fibers(_timed(eng, lat), n_txns)
    fallbacks = (res.get("passthru_fallbacks", 0) +
                 res.get("wal_passthru_degrades", 0))
    assert fallbacks >= 1, "no passthrough op ever degraded"
    emit("faults/passthru/fallbacks", fallbacks,
         f"pool={res.get('passthru_fallbacks', 0)} "
         f"wal={res.get('wal_passthru_degrades', 0)} "
         f"injected={res.get('faults_injected', 0)}")
    emit("faults/passthru/goodput_tps", round(res["tps"]),
         f"p99_us={_pct(lat, 0.99):.0f}")

    section("semisync degrade under link flaps (faults/semisync)")
    from dataclasses import replace

    from repro.replication import ReplicatedCluster
    # full-failure window early in the run (every send resets, the
    # link stays down), then a clean tail so the standby can catch up
    spec = FaultSpec(seed=3, sock_reset=0.01, flap_duration=100e-6,
                     windows=((50e-6, 450e-6, {"sock_reset": 1.0}),))
    ladder = {c.name: c for c in EngineConfig.ladder()}
    cfg = replace(ladder["+SemiSync"], n_fibers=64, pool_frames=1024,
                  faults=spec)
    cl = ReplicatedCluster(cfg, n_tuples=20_000,
                           spec=NVMeSpec(**ENTERPRISE),
                           ack_timeout=100e-6)
    e = cl.primary
    res = cl.run(lambda rng, en=e: ycsb_update_txn(en, rng), n_txns)
    assert res["semisync_degrades"] >= 1, \
        "link-flap storm never tripped the ack-timeout watchdog"
    emit("faults/semisync/degrades", res["semisync_degrades"],
         f"repromotions={res['repromotions']} "
         f"still_degraded={int(cl.degraded)}")
    emit("faults/semisync/repromotions", res["repromotions"])
    emit("faults/semisync/resets", res["sock_resets"],
         f"reconnects={res['repl_reconnects']} "
         f"send_errors={res['repl_send_errors']} "
         f"standby_resets={res['standby_conn_resets']} "
         f"dup_spans={res['dup_spans']}")
    emit("faults/semisync/commit_us", round(res["commit_wait_us"], 1),
         f"tps_acked={res['tps_acked']:.0f} acks={res['acks']}")
    findings = diagnose(report_from_result(res))
    assert any(f.rule == "semisync-degraded" for f in findings)
    top = findings[0]
    emit("faults/semisync/diagnosis", top.rung,
         f"rule={top.rule} severity={top.severity:.3f}")

    section("crash mid-storm durability audit (faults/storm)")
    spec = FaultSpec(seed=23, read_eio=0.01, write_eio=0.02,
                     fsync_fail=0.01, short_read=0.01)
    eng = _engine("group", faults=spec, n_fibers=32, n_tuples=8_000,
                  frames=128)
    acked = []

    def fiber(fid):
        rng = np.random.default_rng(1000 + fid)
        while True:
            t = eng.begin()
            key = fid * 250 + int(rng.integers(0, 250))
            val = bytes(eng.cfg.value_size)
            yield from t.update(key, val)
            yield from eng.commit(t)
            acked.append(t.id)

    for fid in range(32):
        eng.sched.spawn(fiber(fid))
    budget = {"left": 6000}          # fixed step budget: crash point is
                                     # deterministic, mid-storm

    def out_of_budget():
        budget["left"] -= 1
        return budget["left"] <= 0
    eng.sched.run(until=out_of_budget)
    assert acked, "storm run acked nothing before the crash"
    data, log = eng.crash_images()
    rec, rep = recover(data, log, pool_frames=512)
    lost = sorted(set(acked) - rep.winners)
    emit("faults/storm/acked_lost", len(lost),
         f"acked={len(acked)} winners={len(rep.winners)} "
         f"injected={eng.faults.total_injected} MUST be 0")
    assert not lost, f"acked txns lost under fault storm: {lost[:5]}"
    emit("faults/storm/injected", eng.faults.total_injected,
         " ".join(f"{c}={n}" for c, n in sorted(eng.faults.injected.items())
                  if n))
    emit("faults/storm/retries",
         eng.wal.stats.io_retries + eng.pool.read_retries +
         eng.pool.write_retries,
         f"flush_errors={eng.wal.stats.flush_errors}")
