"""Open-loop SLO benches (repro.observe.slo): tail latency vs offered
load, against declared SLOs.

The closed-loop benches elsewhere measure capacity; these measure what
a CLIENT sees when arrivals are open-loop Poisson and the engine must
keep up or shed.  Two workloads, each swept over offered rates from
comfortable to past saturation:

  slo/tpcc   TPC-C-lite mix on the durable single-node engine
             (+GroupCommit).  SLO: p99 <= 10 ms, p999 <= 25 ms.

  slo/repl   YCSB updates on a semisync replicated cluster — every
             commit waits for the standby's WAL-durable ack, so the
             network round trip sits inside the measured latency.
             SLO: p99 <= 15 ms, p999 <= 40 ms.

Rows per (workload, rate): p50/p99/p999/mean arrival-to-completion
latency (queue wait included — no coordinated omission), achieved
throughput, drop count/fraction at the bounded arrival queue, and a
0/1 ``slo_met`` verdict.  The declared SLO is echoed as its own row so
a snapshot is self-contained.  All of it lands in ``BENCH_pr*.json``
and is watched by ``scripts/bench_diff.py``.
"""

from dataclasses import replace

from benchmarks.common import emit, section
from repro.core import NVMeSpec
from repro.observe import slo
from repro.replication import ReplicatedCluster
from repro.storage.engine import EngineConfig, StorageEngine
from repro.storage.workloads import TPCCLite, ycsb_update_txn

ENTERPRISE = dict(plp=True, fsync_lat=30e-6)

LADDER = {c.name: c for c in EngineConfig.ladder()}

#: offered rates (txn/s): comfortable, busy, past saturation (closed-
#: loop capacity is ~150k tps for the TPC-C engine, ~90k acked for the
#: semisync cluster — the top rate overloads both, so the sweep shows
#: the queueing knee and the shed path).  The same rates run in smoke
#: mode (shorter duration, smaller engine) so row names line up across
#: smoke and full snapshots.
TPCC_RATES = (10_000, 50_000, 200_000)
REPL_RATES = (10_000, 50_000, 150_000)

TPCC_SLO = dict(slo_p99_us=10_000.0, slo_p999_us=25_000.0)
REPL_SLO = dict(slo_p99_us=15_000.0, slo_p999_us=40_000.0)


def _emit_rows(prefix: str, rows, slo_cfg) -> None:
    for r in rows:
        base = f"{prefix}/rate={r['rate_tps']:.0f}"
        note = (f"offered={r['offered']} completed={r['completed']} "
                f"achieved={r['achieved_tps']:.0f}/s")
        emit(f"{base}/p50_us", round(r["p50_us"], 1))
        emit(f"{base}/p99_us", round(r["p99_us"], 1),
             f"slo={slo_cfg['slo_p99_us']:.0f}us")
        emit(f"{base}/p999_us", round(r["p999_us"], 1),
             f"slo={slo_cfg['slo_p999_us']:.0f}us")
        emit(f"{base}/mean_us", round(r["mean_us"], 1))
        emit(f"{base}/achieved_tps", round(r["achieved_tps"]), note)
        emit(f"{base}/dropped", r["dropped"],
             f"of {r['offered']} offered (bounded arrival queue)")
        emit(f"{base}/drop_frac", round(r["drop_frac"], 4))
        emit(f"{base}/slo_met", int(r["slo_met"]),
             "1 = p99/p999 within SLO and <1% shed")
    emit(f"{prefix}/slo_p99_us", slo_cfg["slo_p99_us"], "declared")
    emit(f"{prefix}/slo_p999_us", slo_cfg["slo_p999_us"], "declared")


def run(duration_s: float = 0.25, n_tuples: int = 20_000,
        n_workers: int = 64):
    section("open-loop TPC-C vs SLO (slo/tpcc)")
    W = 1

    def mk_tpcc():
        cfg = replace(LADDER["+GroupCommit"], n_fibers=n_workers,
                      pool_frames=4096)
        rows = W * (TPCCLite.ITEMS_PER_WH + TPCCLite.CUST_PER_WH)
        return StorageEngine(cfg, n_tuples=rows + 100,
                             spec=NVMeSpec(**ENTERPRISE))

    def tpcc_txn_for(engine):
        tp = TPCCLite(engine, W)
        return lambda rng: tp.txn(rng)

    rows = slo.sweep(mk_tpcc, tpcc_txn_for, rates=list(TPCC_RATES),
                     duration_s=duration_s, n_workers=n_workers,
                     **TPCC_SLO)
    _emit_rows("slo/tpcc", rows, TPCC_SLO)

    section("open-loop replicated YCSB vs SLO (slo/repl)")

    def mk_repl():
        cfg = replace(LADDER["+SemiSync"], n_fibers=n_workers,
                      pool_frames=1024)
        return ReplicatedCluster(cfg, n_tuples=n_tuples,
                                 spec=NVMeSpec(**ENTERPRISE))

    def repl_txn_for(cluster):
        eng = cluster.primary
        return lambda rng: ycsb_update_txn(eng, rng)

    rows = slo.sweep(mk_repl, repl_txn_for, rates=list(REPL_RATES),
                     duration_s=duration_s, n_workers=n_workers,
                     **REPL_SLO)
    _emit_rows("slo/repl", rows, REPL_SLO)
