"""Paper Fig. 11 (egress vs workers/tuple size), Fig. 12 (memory
bandwidth), Fig. 13 (speedup vs epoll), Fig. 14 (network tuning)."""

from benchmarks.common import emit, section
from repro.shuffle import ShuffleConfig, ShuffleSim

MiB = 1 << 20


def run(total=192 * MiB):
    section("shuffle egress (paper Fig. 11)")
    for ts in (64, 512, 4096):
        for nw in (8, 16, 32):
            for zc_s, zc_r, label in [(False, False, "default"),
                                      (True, False, "+zc_send"),
                                      (True, True, "+zc_recv")]:
                r = ShuffleSim(ShuffleConfig(
                    tuple_size=ts, n_workers=nw, zc_send=zc_s,
                    zc_recv=zc_r, total_bytes_per_node=total)).run()
                emit(f"fig11/tuple={ts}/w={nw}/{label}/gib_s",
                     round(r["egress_gib_per_node"], 1),
                     f"gbit={r['egress_gbit_per_node']:.0f}")

    section("shuffle memory bandwidth (paper Fig. 12)")
    for ts in (64, 4096):
        for zc, label in [((False, False), "default"),
                          ((True, True), "zero-copy")]:
            r = ShuffleSim(ShuffleConfig(
                tuple_size=ts, n_workers=32, zc_send=zc[0], zc_recv=zc[1],
                total_bytes_per_node=total)).run()
            emit(f"fig12/tuple={ts}/{label}/mem_gib_s",
                 round(r["mem_gib_s"], 1),
                 f"per_net_byte={r['mem_per_net_byte']:.2f}")

    section("shuffle vs epoll (paper Fig. 13)")
    for ts in (64, 512, 4096):
        base = ShuffleSim(ShuffleConfig(tuple_size=ts, n_workers=16,
                                        iface="epoll",
                                        total_bytes_per_node=total)).run()
        for zc_s, zc_r, label in [(False, False, "uring"),
                                  (True, False, "uring+zc_send"),
                                  (True, True, "uring+zc_recv")]:
            r = ShuffleSim(ShuffleConfig(
                tuple_size=ts, n_workers=16, zc_send=zc_s, zc_recv=zc_r,
                total_bytes_per_node=total)).run()
            sp = (r["egress_gib_per_node"] / base["egress_gib_per_node"])
            emit(f"fig13/tuple={ts}/{label}/speedup", round(sp, 2),
                 f"epoll={base['egress_gib_per_node']:.1f}gib")

    section("network stack tuning (paper Fig. 14)")
    for tuned in (False, True):
        r = ShuffleSim(ShuffleConfig(
            n_nodes=2, n_workers=8, tuple_size=4096, build_probe_table=False,
            zc_send=True, zc_recv=True, tuned_network=tuned,
            total_bytes_per_node=total)).run()
        emit(f"fig14/tuned={tuned}/runtime_s",
             round(r["duration_s"], 3), "")
