"""Paper Fig. 11 (egress vs workers/tuple size), Fig. 12 (memory
bandwidth), Fig. 13 (speedup vs epoll), Fig. 14 (network tuning) —
PLUS the engine-vs-oracle cross-validation.

Two implementations run here:

  * ``ShuffleSim``   — the closed-form analytical oracle (fast; scans
    the whole Fig. 11/12 parameter grid);
  * ``ShuffleEngine``— the ring-driven engine: every byte moves through
    SEND/RECV SQEs, multishot recv + provided buffer rings, per-worker
    rings on a multi-core fiber scheduler.  Fig. 13's uring-vs-epoll
    speedup and all syscall counts come from the ENGINE's measured
    ``RingStats.enters`` — nothing is hand-amortized.

The final section reports the engine/oracle egress delta per config so
a timing-model regression in either implementation is immediately
visible in CI (the 20% acceptance band is asserted in
tests/test_shuffle.py).
"""

from benchmarks.common import emit, emit_attribution, section
from repro.shuffle import ShuffleConfig, ShuffleSim
from repro.shuffle.engine import ShuffleEngine

MiB = 1 << 20


def run(total=192 * MiB, smoke=False):
    if smoke:
        total = 6 * MiB
    # oracle grid: full paper scale; engine runs: moderated sizes (the
    # per-SQE engine is ~20x slower in wall time than the closed form)
    e_nodes, e_workers = (3, 4) if smoke else (6, 16)
    e_total = total if smoke else 48 * MiB

    section("shuffle egress, analytical oracle (paper Fig. 11)")
    for ts in (64, 512, 4096):
        for nw in (8, 16, 32):
            for zc_s, zc_r, label in [(False, False, "default"),
                                      (True, False, "+zc_send"),
                                      (True, True, "+zc_recv")]:
                r = ShuffleSim(ShuffleConfig(
                    tuple_size=ts, n_workers=nw, zc_send=zc_s,
                    zc_recv=zc_r, total_bytes_per_node=total)).run()
                emit(f"fig11/tuple={ts}/w={nw}/{label}/gib_s",
                     round(r["egress_gib_per_node"], 1),
                     f"gbit={r['egress_gbit_per_node']:.0f}")

    section("shuffle memory bandwidth (paper Fig. 12)")
    for ts in (64, 4096):
        for zc, label in [((False, False), "default"),
                          ((True, True), "zero-copy")]:
            r = ShuffleSim(ShuffleConfig(
                tuple_size=ts, n_workers=32, zc_send=zc[0], zc_recv=zc[1],
                total_bytes_per_node=total)).run()
            emit(f"fig12/tuple={ts}/{label}/mem_gib_s",
                 round(r["mem_gib_s"], 1),
                 f"per_net_byte={r['mem_per_net_byte']:.2f}")

    section("RING-DRIVEN shuffle vs epoll (paper Fig. 13, measured)")
    for ts in (64, 512, 4096):
        kw = dict(tuple_size=ts, n_nodes=e_nodes, n_workers=e_workers,
                  total_bytes_per_node=e_total)
        base = ShuffleEngine(ShuffleConfig(iface="epoll", **kw)).run()
        for zc_s, zc_r, label in [(False, False, "uring"),
                                  (True, False, "uring+zc_send"),
                                  (True, True, "uring+zc_recv")]:
            r = ShuffleEngine(ShuffleConfig(
                zc_send=zc_s, zc_recv=zc_r, **kw)).run()
            sp = r["egress_gib_per_node"] / base["egress_gib_per_node"]
            emit(f"fig13/tuple={ts}/{label}/speedup", round(sp, 2),
                 f"epoll={base['egress_gib_per_node']:.1f}gib "
                 f"enters={r['enters']}vs{base['enters']} "
                 f"batch={r['batch_eff']:.1f} "
                 f"ms_cqes={r['multishot_cqes']} zc={r['zc_notifs']}")
            if ts == 4096:
                # fat tuples: copy-vs-zc shows up as bounce_copy vs
                # zc_setup in the breakdown
                emit_attribution(f"fig13/tuple={ts}/{label}",
                                 r["attribution"],
                                 r["app_cpu_s"] + r["sqpoll_cpu_s"])

    section("network stack tuning (paper Fig. 14)")
    for tuned in (False, True):
        kw = dict(n_nodes=2, n_workers=8, tuple_size=4096,
                  build_probe_table=False, zc_send=True, zc_recv=True,
                  tuned_network=tuned, total_bytes_per_node=total)
        r = ShuffleSim(ShuffleConfig(**kw)).run()
        e = ShuffleEngine(ShuffleConfig(**kw)).run()
        emit(f"fig14/tuned={tuned}/runtime_s",
             round(r["duration_s"], 4),
             f"engine={e['duration_s']:.4f}")

    section("engine vs oracle cross-validation (egress delta)")
    for ts, zc in [(512, False), (4096, False), (512, True)]:
        kw = dict(tuple_size=ts, n_nodes=3, n_workers=e_workers,
                  zc_send=zc, zc_recv=zc,
                  total_bytes_per_node=min(e_total, 16 * MiB))
        e = ShuffleEngine(ShuffleConfig(**kw)).run()
        o = ShuffleSim(ShuffleConfig(**kw)).run()
        ratio = e["egress_gib_per_node"] / o["egress_gib_per_node"]
        emit(f"xval/tuple={ts}/zc={zc}/engine_over_oracle",
             round(ratio, 3),
             f"engine={e['egress_gib_per_node']:.2f} "
             f"oracle={o['egress_gib_per_node']:.2f} "
             f"syscalls={e['syscalls']}")

    # formerly the oracle's blind spot (ROADMAP gap (a), now closed):
    # extreme fan-in at 6 nodes x 32 workers with probe-bound tuples.
    # ShuffleSim now models the receive-side queueing feedback that
    # builds once flows outgrow the provided-buffer ring (exhaustion
    # drain, bounded sender socket buffer, fiber-burst memory-meter
    # convoy), so this ratio sits at ~1.0 like the 3-node cases above.
    # Emitted into the --json snapshot so agreement is tracked per PR;
    # the [0.95, 1.05] band is pinned in tests/test_shuffle.py.
    if not smoke:
        kw = dict(tuple_size=512, n_nodes=6, n_workers=32,
                  total_bytes_per_node=48 * MiB)
        e = ShuffleEngine(ShuffleConfig(**kw)).run()
        o = ShuffleSim(ShuffleConfig(**kw)).run()
        ratio = e["egress_gib_per_node"] / o["egress_gib_per_node"]
        emit("xval/6x32/tuple=512/engine_over_oracle", round(ratio, 3),
             f"engine={e['egress_gib_per_node']:.2f} "
             f"oracle={o['egress_gib_per_node']:.2f} "
             f"rx_gap_pct={round((1 - ratio) * 100, 1)}")
