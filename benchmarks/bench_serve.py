"""Serving-tier KV paging: decode ladder + open-loop token SLO.

``serve/ladder/<rung>``: miss-heavy long-context decode over the
pager's buffer pool.  The config is GUARANTEED-MISS — each sequence's
walk (128 blocks) exceeds the 96-frame HBM pool, so every rung faults
on every block regardless of interleave, and only 16 of the backing
pages fit the host-DRAM spill tier: misses hit the NVMe cold tier at
its 70 us read latency.  That pins the ladder's two regimes:

* sync / +Batch / +RegBufs are LATENCY-bound — demand misses serialize
  into every token, ring CPU savings buy nothing (the paper's "when
  NOT to use it");
* +Prefetch(k) overlaps the spill reads with decode via read-ahead
  fibers and makes the pager CPU-bound, where +PassthruRead's
  storage-stack bypass (io_uring-cmd reads) shows up as tokens/s.

``serve/slo/rate=<r>``: the top rung under open-loop Poisson decode
arrivals (repro.observe.slo) — arrival-to-emit token latency vs a
declared p99 SLO, with bounded-queue shedding past saturation.  Same
rates in smoke and full runs so rows line up for bench_diff.
"""

from collections import deque

from benchmarks.common import emit, emit_attribution, section
from repro.observe import slo
from repro.serve.kv_paging import KVPager, PagerConfig

#: calibrated miss-heavy geometry (see module docstring); n_seqs * k
#: = 64 prefetched frames stay within ~0.75x of the 96-frame pool
LADDER_KW = dict(prefetch_k=8, n_hbm_pages=96, host_pages=16,
                 nvme_pages=2048, page_tokens=16, head_dim=32)
N_SEQS, N_BLOCKS = 8, 128

#: offered decode rates (tokens/s): comfortable, busy, past saturation
#: (closed-loop top-rung capacity is ~3.3k tok/s — the top rate
#: overloads it, showing the queueing knee and the shed path)
SERVE_RATES = (1_000, 2_500, 5_000)
SERVE_SLO = dict(slo_p99_us=20_000.0)


def _mk_pager(cfg: PagerConfig) -> KVPager:
    p = KVPager(cfg)
    p.prefill(n_seqs=N_SEQS, n_blocks=N_BLOCKS, seed=1)
    return p


def _decode_txn_for(pager: KVPager):
    """One 'transaction' = one decode step; sequences are leased from
    a free list so at most n_seqs decodes run concurrently."""
    free = deque(pager.seqs)

    def make_txn(rng):
        def txn():
            s = free.popleft()
            try:
                yield from pager.decode_step(s)
            finally:
                free.append(s)
        return txn()
    return make_txn


def run(n_tokens: int = 4, duration_s: float = 0.1):
    section("KV-paging serving ladder (serve/ladder)")
    base = None
    for cfg in PagerConfig.ladder(**LADDER_KW):
        p = _mk_pager(cfg)
        r = p.run_decode(n_tokens=n_tokens)
        if base is None:
            base = r["tok_s"]
        emit(f"serve/ladder/{cfg.name}/tok_s", round(r["tok_s"]),
             f"x={r['tok_s'] / base:.2f} demand={r['demand_faults']} "
             f"prefetch={r['prefetch_reads']} cold={r['cold_reads']} "
             f"passthru={r['passthru_cmds']} "
             f"batch_eff={r['batch_eff']:.1f}")
        emit(f"serve/ladder/{cfg.name}/p50_us", round(r["p50_us"], 1),
             "token latency")
        emit(f"serve/ladder/{cfg.name}/p99_us", round(r["p99_us"], 1))
        emit_attribution(f"serve/ladder/{cfg.name}", r["attribution"],
                         r["app_cpu_s"] + r["sqpoll_cpu_s"])

    section("open-loop decode vs token SLO (serve/slo)")
    top = PagerConfig.ladder(**LADDER_KW)[-1]
    rows = slo.sweep(lambda: _mk_pager(top), _decode_txn_for,
                     rates=list(SERVE_RATES), duration_s=duration_s,
                     n_workers=N_SEQS, queue_cap=64, **SERVE_SLO)
    for r in rows:
        name = f"serve/slo/rate={r['rate_tps']:.0f}"
        note = (f"offered={r['offered']} completed={r['completed']} "
                f"achieved={r['achieved_tps']:.0f}/s")
        emit(f"{name}/p50_us", round(r["p50_us"], 1))
        emit(f"{name}/p99_us", round(r["p99_us"], 1),
             f"slo={SERVE_SLO['slo_p99_us']:.0f}us")
        emit(f"{name}/p999_us", round(r["p999_us"], 1))
        emit(f"{name}/mean_us", round(r["mean_us"], 1))
        emit(f"{name}/achieved_tps", round(r["achieved_tps"]), note)
        emit(f"{name}/dropped", r["dropped"],
             f"of {r['offered']} offered (bounded arrival queue)")
        emit(f"{name}/drop_frac", round(r["drop_frac"], 4))
        emit(f"{name}/slo_met", int(r["slo_met"]),
             "1 = p99 within SLO and <1% shed")
    emit("serve/slo/slo_p99_us", SERVE_SLO["slo_p99_us"], "declared")
