"""Framework roofline: reads the dry-run JSON artifacts and prints the
three-term roofline per (arch x shape x mesh) — the §Roofline source."""

import glob
import json
import os

from benchmarks.common import emit, section

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run():
    section("roofline table from dry-run artifacts (EXPERIMENTS §Roofline)")
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        emit("roofline/missing", 0, "run: python -m repro.launch.dryrun --all")
        return
    for fn in files:
        with open(fn) as f:
            r = json.load(f)
        cell = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") != "ok":
            emit(f"roofline/{cell}/skipped", 0, r.get("status", "?"))
            continue
        if r.get("tag"):
            continue                     # hillclimb variants listed in §Perf
        t = r["roofline"]
        emit(f"roofline/{cell}/bound_s", round(t["t_bound_s"], 4),
             f"bottleneck={t['bottleneck']} "
             f"comp={t['t_compute_s']:.3f} mem={t['t_memory_s']:.3f} "
             f"coll={t['t_collective_s']:.3f} "
             f"useful={r['useful_flops_frac']:.2f} "
             f"hbm_gib={r['memory']['peak_est_bytes']/2**30:.1f}")
